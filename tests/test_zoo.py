"""Model-zoo pipeline tests (ISSUE 5, DESIGN.md §15).

Four layers:

* **round-trip** (parametrized, fast path) — every config in
  ``configs.registry`` traces through the kernels/HLO path to a non-empty
  costed ``Program`` and a finite node estimate (the cheapest phase only;
  the full train/prefill/decode sweep is slow-marked);
* **engine plumbing** (pure python, no jax) — ``estimate_program`` on a
  synthetic program: sandwich invariants, core-count axis, batched O3
  knob grid riding the shard-costed forms;
* **rank stability** — Kendall-tau floor (≥ 0.5) across the core-count
  axis over the committed ``BENCH_model_zoo.json``, per phase, plus
  schema/sanity checks on the artifact (DESIGN.md §16);
* **memoization** — traces are cached in-process and on disk so the
  sweep stays inside its wall-clock budget.
"""
import json
import math
from pathlib import Path

import pytest

from repro.configs import ARCHS, ZOO_SHAPES, zoo_phases_for
from repro.core.hlo import OpStat, Program
from repro.core.hwspec import A64FX_CORE, NodeTopology
from repro.core.zoo import (DEFAULT_CORE_COUNTS, CoreCountEstimate,
                            clear_trace_caches, estimate_program,
                            kendall_tau, phase_model_flops, run_zoo,
                            trace_phase, zoo_config, zoo_o3_knobs)

BENCH_JSON = Path(__file__).resolve().parents[1] / "BENCH_model_zoo.json"
RANK_TAU_FLOOR = 0.5
PORTS = {"mxu", "vpu", "mem", "ici"}


# ------------------------------------------------------ round-trip (fast)
@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_registry_roundtrip_to_costed_program_and_node_estimate(arch):
    """Every registry config -> non-empty costed Program -> finite node
    estimate.  Decode is the cheapest phase (~1s compile); the full
    three-phase sweep is the slow-marked test below."""
    prog = trace_phase(arch, "decode")
    assert len(prog.ops) > 0
    assert prog.flops > 0 and prog.bytes_accessed > 0
    pe = estimate_program(prog, A64FX_CORE, core_counts=(12,),
                          arch=arch, phase="decode")
    assert pe.n_costed > 0
    (ce,) = pe.per_core
    assert math.isfinite(ce.t_est_s) and ce.t_est_s > 0
    assert ce.t_zero_contention_s <= ce.t_est_s * (1 + 1e-9)
    assert ce.bound_by in PORTS
    # busy sums over ports, so overlapped ports can push this above 1;
    # it is positive and bounded by the number of ports
    assert 0.0 < ce.parallel_efficiency <= len(PORTS) + 1e-9


def test_zoo_config_and_flops_helpers():
    cfg = zoo_config("grok-1-314b")
    assert cfg.family == ARCHS["grok-1-314b"].family
    assert cfg.param_count() < 10e6
    for phase, shape in ZOO_SHAPES.items():
        assert phase_model_flops(cfg, shape) > 0
    assert zoo_phases_for(cfg) == ("train", "prefill", "decode")
    with pytest.raises(KeyError):
        zoo_config("no-such-arch")


def test_trace_phase_memoized_and_disk_cached(tmp_path):
    a = trace_phase("chatglm3-6b", "decode")
    b = trace_phase("chatglm3-6b", "decode")
    assert a is b                         # in-process memo
    clear_trace_caches()
    c = trace_phase("chatglm3-6b", "decode", hlo_cache_dir=tmp_path)
    assert c is not a
    cached = list(tmp_path.glob("*.hlo.txt"))
    assert len(cached) == 1               # disk cache written
    clear_trace_caches()
    d = trace_phase("chatglm3-6b", "decode", hlo_cache_dir=tmp_path)
    assert len(d.ops) == len(c.ops)       # warm load, no recompile
    with pytest.raises(ValueError):
        trace_phase("chatglm3-6b", "warmup")


def test_hlo_cache_key_content_hashed(tmp_path, monkeypatch):
    """The disk cache is keyed by a content hash of (config, shape,
    schema version), not the name alone: a config/shape/schema change
    must miss instead of serving stale HLO (regression — the pre-schema-2
    name-only scheme read whatever sat at the name)."""
    import dataclasses

    import repro.core.zoo as zoo
    shape = ZOO_SHAPES["decode"]
    p = zoo.hlo_cache_path(tmp_path, "chatglm3-6b", "decode", shape,
                           "float32")
    # deterministic, and sensitive to every key component
    assert p == zoo.hlo_cache_path(tmp_path, "chatglm3-6b", "decode",
                                   shape, "float32")
    assert p != zoo.hlo_cache_path(tmp_path, "chatglm3-6b", "decode",
                                   shape, "bfloat16")
    bigger = dataclasses.replace(shape, global_batch=shape.global_batch * 2)
    assert p != zoo.hlo_cache_path(tmp_path, "chatglm3-6b", "decode",
                                   bigger, "float32")
    monkeypatch.setattr(zoo, "HLO_CACHE_SCHEMA", zoo.HLO_CACHE_SCHEMA + 1)
    assert p != zoo.hlo_cache_path(tmp_path, "chatglm3-6b", "decode",
                                   shape, "float32")
    monkeypatch.undo()

    # cache busting end to end (no jax: the trace step is stubbed out).
    # A stale name-only entry — the old scheme — is ignored; the hashed
    # path is written and then served warm.
    hlo = ('HloModule m, is_scheduled=true\n\n'
           'ENTRY %main (p: f32[4096]) -> f32[4096] {\n'
           '  %p = f32[4096]{0} parameter(0)\n'
           '  %x = f32[4096]{0} exponential(f32[4096]{0} %p)\n'
           '  ROOT %y = f32[4096]{0} add(f32[4096]{0} %x, f32[4096]{0} %p)\n'
           '}\n')
    monkeypatch.setattr(zoo, "_phase_hlo", lambda *a, **k: hlo)
    stale = tmp_path / (f"chatglm3-6b__decode_s{shape.seq_len}"
                        f"b{shape.global_batch}_float32.hlo.txt")
    stale.write_text("STALE — must not be parsed")
    clear_trace_caches()
    prog = trace_phase("chatglm3-6b", "decode", hlo_cache_dir=tmp_path)
    assert len(prog.ops) >= 1             # parsed the stub, not the stale
    assert p.exists() and p.read_text() == hlo
    # warm hit: a second process-fresh trace reads the hashed entry even
    # when recompilation is impossible
    monkeypatch.setattr(zoo, "_phase_hlo",
                        lambda *a, **k: pytest.fail("cache miss"))
    clear_trace_caches()
    again = trace_phase("chatglm3-6b", "decode", hlo_cache_dir=tmp_path)
    assert len(again.ops) == len(prog.ops)
    clear_trace_caches()


# -------------------------------------------- engine plumbing (no jax)
def synthetic_program(n_ops: int = 48) -> Program:
    """A mixed compute/memory DAG: enough DRAM streaming that the node
    contention model has something to divide."""
    ops = []
    for i in range(n_ops):
        if i % 3 == 0:
            ops.append(OpStat(f"cp{i}", "copy", "data", "f32",
                              bytes_accessed=8 * 2**20,
                              read_bytes=6 * 2**20, write_bytes=2 * 2**20))
        else:
            ops.append(OpStat(f"e{i}", "add", "elementwise", "f32",
                              flops=5e7, bytes_accessed=2**18,
                              deps=[i - 1], dep_bytes=[2**16]))
    return Program(ops=ops, entry="e", n_partitions=1)


def test_estimate_program_core_axis_and_sandwich():
    prog = synthetic_program()
    pe = estimate_program(prog, A64FX_CORE,
                          core_counts=DEFAULT_CORE_COUNTS,
                          arch="syn", phase="train")
    assert [ce.n_cores for ce in pe.per_core] == list(DEFAULT_CORE_COUNTS)
    prev = None
    for ce in pe.per_core:
        assert math.isfinite(ce.t_est_s) and ce.t_est_s > 0
        assert ce.t_zero_contention_s <= ce.t_est_s * (1 + 1e-9)
        if prev is not None:              # shard mode: more cores never hurt
            assert ce.t_est_s <= prev * (1 + 1e-9)
        prev = ce.t_est_s
    assert pe.node_speedup >= 1.0
    assert pe.roofline_dominant in ("compute", "memory", "collective")
    assert pe.at(12).n_cores == 12
    with pytest.raises(KeyError):
        pe.at(7)


def test_estimate_program_o3_grid_rides_batched_node_engine():
    prog = synthetic_program()
    knobs = zoo_o3_knobs(A64FX_CORE)
    pe = estimate_program(prog, A64FX_CORE, core_counts=(1, 12),
                          o3_knobs=knobs, arch="syn", phase="train")
    for ce in pe.per_core:
        assert ce.t_best_knobs_s > 0
        assert set(ce.best_knobs) == {"inflight_window", "mem_issue_width",
                                      "vpu_issue_width", "queue_depth"}
        # the zoo grid contains the spec's own default knob combo
        # (window 64, mem 2, vpu 1, qdepth 16), and the grid now runs
        # the exact contended engine (DESIGN.md §17) at every core
        # count, so the grid minimum can only beat or tie the node
        # estimate (float-reassociation slack)
        assert ce.t_best_knobs_s <= ce.t_est_s * (1 + 1e-6)


def test_estimate_program_degenerate_topology_matches_single_core():
    """With a contention-free topology and one core, the zoo's estimate
    is the single-core schedule (the node-engine differential contract)."""
    from repro.core.schedule import schedule_program
    prog = synthetic_program()
    pe = estimate_program(prog, A64FX_CORE, core_counts=(1,),
                          topology=NodeTopology.degenerate(1),
                          arch="syn", phase="train")
    ref = schedule_program(prog, A64FX_CORE)
    assert pe.per_core[0].t_est_s == ref.t_est


def test_kendall_tau_self_checks():
    assert kendall_tau([1, 2, 3, 4], [10, 20, 30, 40]) == 1.0
    assert kendall_tau([1, 2, 3, 4], [40, 30, 20, 10]) == -1.0
    assert abs(kendall_tau([1, 2, 3, 4], [10, 20, 40, 30])) < 1.0
    assert kendall_tau([1, 1, 1], [1, 2, 3]) == 0.0


def test_core_count_estimate_cycles():
    ce = CoreCountEstimate(n_cores=1, t_est_s=1e-3,
                           t_zero_contention_s=1e-3,
                           parallel_efficiency=1.0, bound_by="vpu")
    assert ce.cycles(1.8e9) == pytest.approx(1.8e6)


# ------------------------------------------- BENCH artifact (rank floor)
def _bench():
    assert BENCH_JSON.exists(), \
        "run `PYTHONPATH=src python -m benchmarks.model_zoo` and commit " \
        "BENCH_model_zoo.json"
    return json.loads(BENCH_JSON.read_text())


def test_bench_artifact_schema_and_coverage():
    """Acceptance: per-phase node estimates for >= 8 registry configs at
    >= 3 core counts, every estimate finite and sandwiched."""
    d = _bench()
    assert d["schema"] == 1
    assert len(d["core_counts"]) >= 3
    assert len(d["models"]) >= 8
    for arch, m in d["models"].items():
        assert arch in ARCHS
        for phase, p in m["phases"].items():
            assert phase in ZOO_SHAPES
            assert p["n_ops"] > 0 and p["n_costed"] > 0
            assert p["roofline_dominant"] in ("compute", "memory",
                                              "collective")
            assert len(p["per_core"]) >= 3
            for k, ce in p["per_core"].items():
                assert int(k) in d["core_counts"]
                assert math.isfinite(ce["t_est_us"])
                assert ce["t_est_us"] > 0
                assert ce["cycles"] > 0
                assert ce["t_zero_contention_us"] <= \
                    ce["t_est_us"] * (1 + 1e-9)
                assert ce["bound_by"] in PORTS


def test_bench_rank_stability_kendall_floor():
    """The paper's goal is *relative* evaluation: the model ranking the
    node engine reports must be stable across the core-count axis.
    Kendall-tau >= 0.5 between every adjacent pair of swept core counts,
    per phase, recomputed from the raw estimates (not the stored taus)."""
    d = _bench()
    counts = d["core_counts"]
    for phase in d["phases"]:
        archs = [a for a, m in d["models"].items() if phase in m["phases"]]
        assert len(archs) >= 8
        t = {k: [d["models"][a]["phases"][phase]["per_core"][str(k)]
                 ["t_est_us"] for a in archs] for k in counts}
        for lo, hi in zip(counts, counts[1:]):
            tau = kendall_tau(t[lo], t[hi])
            assert tau >= RANK_TAU_FLOOR, (
                f"{phase}: rank stability tau({lo}c vs {hi}c) = {tau:.2f} "
                f"below {RANK_TAU_FLOOR}: core scaling scrambled the "
                "model ordering")
        # stored taus agree with the recomputed ones
        stored = d["kendall_tau"][phase]
        assert stored["min"] >= RANK_TAU_FLOOR


def test_bench_rank_tables_consistent_with_estimates():
    d = _bench()
    for phase, by_count in d["rank"].items():
        for k, ranked in by_count.items():
            ts = [d["models"][a]["phases"][phase]["per_core"][k]["t_est_us"]
                  for a in ranked]
            assert ts == sorted(ts), (phase, k)


# ------------------------------------------------------- full sweep (slow)
@pytest.mark.slow
def test_full_zoo_sweep_all_phases():
    """Slow acceptance: the whole registry through every phase, O3 grid
    on, fresh traces — the exact pipeline behind BENCH_model_zoo.json."""
    report = run_zoo(core_counts=(1, 12, 48), with_o3_grid=True)
    assert set(report.estimates) == set(ARCHS)
    for arch, by_phase in report.estimates.items():
        assert set(by_phase) == set(ZOO_SHAPES)
        for pe in by_phase.values():
            assert pe.n_costed > 0
            for ce in pe.per_core:
                assert math.isfinite(ce.t_est_s) and ce.t_est_s > 0
    for phase in report.phases:
        assert report.rank_stability(phase)["min"] >= RANK_TAU_FLOOR
    d = report.to_dict()
    assert len(d["models"]) == len(ARCHS)
